//! The PJRT engine: load HLO-text artifacts, compile once per entry point,
//! execute from the hot path with device-resident buffers.
//!
//! Design points (EXPERIMENTS.md §Perf):
//!
//! * **Compile once** — executables are cached per entry name; compiles
//!   happen at startup (`precompile`) or on first use.
//! * **Device-resident state** — `execute` uses the patched
//!   `execute_b_untupled`, so a tuple-rooted computation returns one buffer
//!   per element.  Params, optimizer state, token buffers, and KV caches
//!   never round-trip through the host between calls; only small arrays
//!   (sampled tokens, log-probs, scores) are downloaded each chunk.
//! * **Thread-safe** — PJRT's compile/execute are thread-safe; the actor
//!   and reward workers execute concurrently from their own threads, which
//!   is what realizes intra-step overlap on this backend.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::Manifest;

/// Cumulative per-entry execution counters (lock-free reads on the hot path).
#[derive(Default)]
pub struct EntryStats {
    pub calls: AtomicU64,
    pub nanos: AtomicU64,
}

/// PJRT engine over one artifact directory.
pub struct Engine {
    client: PjRtClient,
    manifest: Manifest,
    executables: RwLock<HashMap<String, Arc<PjRtLoadedExecutable>>>,
    stats: Mutex<HashMap<String, Arc<EntryStats>>>,
    /// per-stage aggregation ("actor" / "reward" / "ref" / "main") so the
    /// utilization analysis can attribute device time to pipeline stages
    scope_stats: Mutex<HashMap<String, Arc<EntryStats>>>,
}

impl Engine {
    /// Create a CPU-PJRT engine over `dir` (must contain `manifest.json`).
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().map_err(anyhow::Error::from).context("PJRT CPU client")?;
        log::info!(
            "engine: platform={} devices={} entries={}",
            client.platform_name(),
            client.device_count(),
            manifest.entries.len()
        );
        Ok(Self {
            client,
            manifest,
            executables: RwLock::new(HashMap::new()),
            stats: Mutex::new(HashMap::new()),
            scope_stats: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// Get (compiling + caching on first use) an entry's executable.
    pub fn executable(&self, name: &str) -> Result<Arc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.executables.read().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.entry(name)?;
        let path = self.manifest.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(anyhow::Error::from)
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(anyhow::Error::from)
            .with_context(|| format!("compiling {name}"))?;
        log::debug!("compiled {name} in {:.2?}", t0.elapsed());
        let exe = Arc::new(exe);
        self.executables.write().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Compile a set of entries up front (startup, off the hot path).
    pub fn precompile(&self, names: &[&str]) -> Result<()> {
        for name in names {
            self.executable(name)?;
        }
        Ok(())
    }

    /// Execute an entry with device-resident arguments; returns one buffer
    /// per output tuple element.  Validates arity against the manifest.
    /// Time is attributed to the `"main"` scope — stage workers use
    /// [`Self::execute_scoped`] so per-stage utilization can be read back.
    pub fn execute(&self, name: &str, args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        self.execute_scoped("main", name, args)
    }

    /// [`Self::execute`] with the elapsed time also attributed to `scope`
    /// (one scope per pipeline stage: "actor", "reward", "ref", ...).
    pub fn execute_scoped(
        &self,
        scope: &str,
        name: &str,
        args: &[&PjRtBuffer],
    ) -> Result<Vec<PjRtBuffer>> {
        let spec = self.manifest.entry(name)?;
        if args.len() != spec.inputs.len() {
            bail!("{name}: got {} args, manifest says {}", args.len(), spec.inputs.len());
        }
        let exe = self.executable(name)?;
        let t0 = Instant::now();
        let mut outs = exe
            .execute_b_untupled(args)
            .map_err(anyhow::Error::from)
            .with_context(|| format!("executing {name}"))?;
        let elapsed = t0.elapsed().as_nanos() as u64;
        let stats = self.entry_stats(name);
        stats.calls.fetch_add(1, Ordering::Relaxed);
        stats.nanos.fetch_add(elapsed, Ordering::Relaxed);
        let sstats = self.scope_entry_stats(scope);
        sstats.calls.fetch_add(1, Ordering::Relaxed);
        sstats.nanos.fetch_add(elapsed, Ordering::Relaxed);

        if outs.len() != 1 {
            bail!("{name}: expected 1 replica, got {}", outs.len());
        }
        let bufs = outs.pop().unwrap();
        if bufs.len() != spec.outputs.len() {
            bail!("{name}: got {} outputs, manifest says {}", bufs.len(), spec.outputs.len());
        }
        Ok(bufs)
    }

    fn entry_stats(&self, name: &str) -> Arc<EntryStats> {
        let mut map = self.stats.lock().unwrap();
        map.entry(name.to_string()).or_default().clone()
    }

    fn scope_entry_stats(&self, scope: &str) -> Arc<EntryStats> {
        let mut map = self.scope_stats.lock().unwrap();
        map.entry(scope.to_string()).or_default().clone()
    }

    /// Snapshot of (entry, calls, total_seconds), sorted by time desc.
    pub fn stats_snapshot(&self) -> Vec<(String, u64, f64)> {
        Self::snapshot(&self.stats)
    }

    /// Snapshot of (stage scope, calls, total_seconds), sorted by time desc.
    pub fn scope_snapshot(&self) -> Vec<(String, u64, f64)> {
        Self::snapshot(&self.scope_stats)
    }

    fn snapshot(stats: &Mutex<HashMap<String, Arc<EntryStats>>>) -> Vec<(String, u64, f64)> {
        let map = stats.lock().unwrap();
        let mut rows: Vec<(String, u64, f64)> = map
            .iter()
            .map(|(k, v)| {
                (k.clone(), v.calls.load(Ordering::Relaxed),
                 v.nanos.load(Ordering::Relaxed) as f64 * 1e-9)
            })
            .collect();
        rows.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        rows
    }

    // ---- host <-> device helpers ----

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None).map_err(anyhow::Error::from)
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None).map_err(anyhow::Error::from)
    }

    pub fn upload_u32(&self, data: &[u32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None).map_err(anyhow::Error::from)
    }

    pub fn zeros_f32(&self, dims: &[usize]) -> Result<PjRtBuffer> {
        let n: usize = dims.iter().product::<usize>().max(1);
        self.upload_f32(&vec![0.0; n], dims)
    }

    pub fn scalar_i32(&self, x: i32) -> Result<PjRtBuffer> {
        self.upload_i32(&[x], &[])
    }

    // Downloads go through a host literal: the CPU PJRT plugin does not
    // implement CopyRawToHost.  Only small tensors (tokens, log-probs,
    // scores, stats) are downloaded on the hot path.
    pub fn download_f32(&self, buf: &PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf.to_literal_sync().map_err(anyhow::Error::from)?;
        lit.to_vec::<f32>().map_err(anyhow::Error::from)
    }

    pub fn download_i32(&self, buf: &PjRtBuffer) -> Result<Vec<i32>> {
        let lit = buf.to_literal_sync().map_err(anyhow::Error::from)?;
        lit.to_vec::<i32>().map_err(anyhow::Error::from)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        std::path::Path::new(dir).join("manifest.json").exists().then(|| {
            Engine::load(dir).expect("engine loads")
        })
    }

    #[test]
    fn upload_download_roundtrip() {
        let Some(e) = engine() else { return };
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.5).collect();
        let buf = e.upload_f32(&data, &[3, 4]).unwrap();
        assert_eq!(e.download_f32(&buf).unwrap(), data);
        let ints: Vec<i32> = (0..6).collect();
        let buf = e.upload_i32(&ints, &[6]).unwrap();
        assert_eq!(e.download_i32(&buf).unwrap(), ints);
    }

    #[test]
    fn gae_executes_and_matches_rust_mirror() {
        let Some(e) = engine() else { return };
        let m = e.manifest().shape.clone();
        let (b, s) = (m.ppo_batch, m.s_max);
        let mut rewards = vec![0f32; b * s];
        let mut values = vec![0f32; b * s];
        let mut mask = vec![0f32; b * s];
        for i in 0..b {
            for t in 0..10 {
                rewards[i * s + t] = (t as f32 * 0.3).sin();
                values[i * s + t] = (t as f32 * 0.1).cos();
                mask[i * s + t] = 1.0;
            }
        }
        let args = [
            e.upload_f32(&rewards, &[b, s]).unwrap(),
            e.upload_f32(&values, &[b, s]).unwrap(),
            e.upload_f32(&mask, &[b, s]).unwrap(),
        ];
        let arg_refs: Vec<&PjRtBuffer> = args.iter().collect();
        let outs = e.execute("gae", &arg_refs).unwrap();
        assert_eq!(outs.len(), 2);
        let adv = e.download_f32(&outs[0]).unwrap();

        let (want_adv, _) = crate::ppo::gae::gae(
            &rewards, &values, &mask, b, s, m.gamma as f32, m.lam as f32,
        );
        for (a, w) in adv.iter().zip(&want_adv) {
            assert!((a - w).abs() < 1e-4, "{a} vs {w}");
        }
        // stats recorded
        let snap = e.stats_snapshot();
        assert!(snap.iter().any(|(n, c, _)| n == "gae" && *c == 1));
    }

    #[test]
    fn arity_mismatch_is_rejected() {
        let Some(e) = engine() else { return };
        let buf = e.upload_f32(&[0.0], &[1]).unwrap();
        assert!(e.execute("gae", &[&buf]).is_err());
    }
}
