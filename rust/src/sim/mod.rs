//! Discrete-event GPU-cluster simulator — the testbed substitute
//! (DESIGN.md §1) that regenerates the paper's evaluation at Qwen-7B /
//! 8×H200 scale.
//!
//! The real-compute path (runtime + coordinator) proves the *algorithm*;
//! this simulator reproduces the *efficiency claims*: per-stage roofline
//! cost models (decode is HBM-bandwidth-bound, prefill/training are
//! compute-bound), long-tailed and phase-evolving rollout lengths, reward
//! dynamics with staleness penalties, colocation contention, and multi-node
//! networking — enough structure for every figure/table shape of §2 and §4
//! (who wins, by what factor, where crossovers fall).
//!
//! * [`gpu`] — device specs (A40 / A100 / H200 / GH200) + utilization
//!   accounting;
//! * [`costmodel`] — model FLOPs/bytes and per-stage latency rooflines;
//! * [`lengths`] — long-tail response-length distributions (Fig. 2b);
//! * [`rewardmodel`] — reward-vs-step dynamics + staleness (Fig. 2c);
//! * [`cluster`] — GPU pools, colocation, nodes, interconnect;
//! * [`pipeline`] — the schedules under study: TRL-sequential, OPPO (full +
//!   ablations + fixed Δ), async staleness-k, VeRL DP / DP+SP / fully-async
//!   w/ SP, AReaL;
//! * [`presets`] — the paper's four experimental setups, calibrated so the
//!   TRL baseline's stage shares match the paper's reported behaviour;
//! * [`env`] — the simulator wrapped as a gym-style environment
//!   ([`env::PipelineEnv`]) plus the Q-policy training loop behind
//!   `oppo train-controller`.

pub mod cluster;
pub mod costmodel;
pub mod env;
pub mod gpu;
pub mod lengths;
pub mod pipeline;
pub mod presets;
pub mod rewardmodel;

pub use cluster::ClusterSetup;
pub use costmodel::ModelSpec;
pub use env::{train_qpolicy, PipelineEnv, TrainReport};
pub use gpu::GpuSpec;
pub use lengths::LengthModel;
pub use pipeline::{
    kv_lane_bounds, simulate, Pipeline, SimAdmission, SimConfig, SimController, SimCore, SimKnobs,
};
pub use presets::Setup;
pub use rewardmodel::RewardCurve;
