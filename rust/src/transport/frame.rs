//! Length-prefixed binary frames — the unit of the multi-node stage wire.
//!
//! Every message between a coordinator and a remote stage replica travels
//! as one frame:
//!
//! ```text
//! +------+---------+------+-----------+----------+=========+
//! | MAGIC| VERSION | KIND | LEN (u32) | CRC (u32)| payload |
//! | 4 B  |   1 B   | 1 B  |   LE      |   LE     |  LEN B  |
//! +------+---------+------+-----------+----------+=========+
//! ```
//!
//! `MAGIC` guards against talking to the wrong service (a mismatch is a
//! hard desync — the reader cannot resynchronize and must drop the
//! connection).  `VERSION` is the framing version; a peer speaking a newer
//! layout is rejected before any payload is interpreted.  `CRC` is IEEE
//! CRC-32 over the payload: a corrupted frame errors *cleanly* — the
//! length prefix was already consumed, so the stream stays aligned and the
//! next frame is still readable (exercised by the corruption proptests).
//!
//! The in-process replica path never touches this module — chunks move as
//! plain `Vec`s through the stage channels, zero-copy as before.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

/// First bytes of every frame ("OPPO Frame").
pub const MAGIC: [u8; 4] = *b"OPFR";
/// Framing layout version this build speaks.
pub const VERSION: u8 = 1;
/// Upper bound on a single frame's payload (a full `[G, C]` chunk at the
/// largest shipped shapes is far below this; anything bigger is a corrupt
/// or hostile length prefix, not a real message).
pub const MAX_PAYLOAD: usize = 1 << 28;

/// IEEE CRC-32 (reflected, poly 0xEDB88320), table-driven.  The offline
/// crate set has no checksum crate; this is the standard 8-bit-index
/// implementation, validated against the known check value in tests.
pub fn crc32(data: &[u8]) -> u32 {
    static TABLE: once_cell::sync::Lazy<[u32; 256]> = once_cell::sync::Lazy::new(|| {
        let mut table = [0u32; 256];
        for (i, slot) in table.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        table
    });
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = TABLE[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

/// Write one frame.  The payload is already-encoded message bytes (see
/// [`wire`](super::wire)); `kind` tags which message type it decodes as.
pub fn write_frame<W: Write>(w: &mut W, kind: u8, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_PAYLOAD {
        bail!("frame payload {} bytes exceeds MAX_PAYLOAD", payload.len());
    }
    let mut header = [0u8; 14];
    header[0..4].copy_from_slice(&MAGIC);
    header[4] = VERSION;
    header[5] = kind;
    header[6..10].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    header[10..14].copy_from_slice(&crc32(payload).to_le_bytes());
    w.write_all(&header).context("writing frame header")?;
    w.write_all(payload).context("writing frame payload")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one frame; returns `(kind, payload)`.
///
/// Error taxonomy (all clean `Err`s, never a panic):
/// * truncated header/payload → "truncated frame" (connection died
///   mid-frame);
/// * bad magic → "bad frame magic" (desynchronized or foreign peer —
///   unrecoverable, drop the connection);
/// * version mismatch → "frame version" (peer speaks a different layout);
/// * crc mismatch → "frame crc mismatch" (payload corrupted in transit;
///   the stream itself is still frame-aligned).
pub fn read_frame<R: Read>(r: &mut R) -> Result<(u8, Vec<u8>)> {
    let mut header = [0u8; 14];
    read_exact(r, &mut header).context("truncated frame (header)")?;
    if header[0..4] != MAGIC {
        bail!("bad frame magic {:02x?} (stream desynchronized?)", &header[0..4]);
    }
    let version = header[4];
    if version != VERSION {
        bail!("frame version {version} unsupported (this build speaks {VERSION})");
    }
    let kind = header[5];
    let len = u32::from_le_bytes([header[6], header[7], header[8], header[9]]) as usize;
    if len > MAX_PAYLOAD {
        bail!("frame length {len} exceeds MAX_PAYLOAD (corrupt length prefix?)");
    }
    let crc = u32::from_le_bytes([header[10], header[11], header[12], header[13]]);
    let mut payload = vec![0u8; len];
    read_exact(r, &mut payload).context("truncated frame (payload)")?;
    let got = crc32(&payload);
    if got != crc {
        bail!("frame crc mismatch: header {crc:#010x}, payload {got:#010x}");
    }
    Ok((kind, payload))
}

fn read_exact<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<()> {
    r.read_exact(buf).map_err(|e| anyhow::anyhow!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_check_value() {
        // the canonical CRC-32/IEEE check: crc32("123456789") == 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn frame_round_trip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 7, b"hello frames").unwrap();
        write_frame(&mut buf, 9, b"").unwrap();
        let mut r = &buf[..];
        let (k1, p1) = read_frame(&mut r).unwrap();
        assert_eq!((k1, p1.as_slice()), (7, b"hello frames".as_slice()));
        let (k2, p2) = read_frame(&mut r).unwrap();
        assert_eq!((k2, p2.len()), (9, 0));
        assert!(read_frame(&mut r).is_err(), "EOF must error, not hang");
    }

    #[test]
    fn corrupted_payload_errors_without_desync() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 1, b"first").unwrap();
        write_frame(&mut buf, 2, b"second").unwrap();
        buf[15] ^= 0xFF; // flip a payload byte of the first frame
        let mut r = &buf[..];
        let err = read_frame(&mut r).unwrap_err();
        assert!(format!("{err}").contains("crc"), "{err}");
        // the length prefix kept the stream aligned: the next frame reads
        let (k, p) = read_frame(&mut r).unwrap();
        assert_eq!((k, p.as_slice()), (2, b"second".as_slice()));
    }

    #[test]
    fn bad_magic_and_version_are_clean_errors() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 1, b"x").unwrap();
        let mut bad = buf.clone();
        bad[0] = b'X';
        let err = read_frame(&mut &bad[..]).unwrap_err();
        assert!(format!("{err}").contains("magic"), "{err}");
        let mut newer = buf;
        newer[4] = VERSION + 1;
        // re-crc not needed: version is checked before the payload
        let err = read_frame(&mut &newer[..]).unwrap_err();
        assert!(format!("{err}").contains("version"), "{err}");
    }

    #[test]
    fn truncated_frame_is_a_clean_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 3, b"payload-to-truncate").unwrap();
        for cut in [0, 5, 13, 14, buf.len() - 1] {
            let err = read_frame(&mut &buf[..cut]).unwrap_err();
            assert!(format!("{err:#}").contains("truncated"), "cut {cut}: {err:#}");
        }
    }
}
