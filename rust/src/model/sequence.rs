//! Per-sequence state: one entry per buffer slot in Algorithm 1's
//! `B + Δ` FIFO.  A sequence owns a generation *lane* (its row in the
//! device-resident token/KV buffers) for its whole life, including across
//! PPO steps when deferred — that is how inter-step overlap preserves
//! partial work (§3.2).

use crate::data::tasks::Prompt;

/// Lifecycle phase of a buffered sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqPhase {
    /// Waiting for prompt prefill on its lane.
    Queued,
    /// Actor is decoding (may span several chunks and several PPO steps).
    Generating,
    /// Hit EOS or the length cap; eligible for the next PPO batch.
    Finished,
}

/// One sequence in the buffer.
#[derive(Clone, Debug)]
pub struct Sequence {
    pub prompt: Prompt,
    /// generation lane (row index in the device buffers), fixed for life
    pub lane: usize,
    pub phase: SeqPhase,
    pub prompt_len: usize,
    /// generated tokens so far (host mirror; device holds the full row)
    pub response: Vec<i32>,
    /// per-generated-token actor log-probs / value estimates
    pub logps: Vec<f32>,
    pub values: Vec<f32>,
    /// PPO step at which the prompt entered the buffer (deferral stats)
    pub enqueued_step: u64,
    /// how many tokens (prompt + response) have been streamed to the
    /// downstream stages' incremental prefill so far — all stages consume
    /// the same contiguous chunk schedule, so one cursor serves every stage
    pub streamed: usize,
    /// reward-model score once scored
    pub rm_score: Option<f32>,
    /// reference-model log-probs accumulated by the streamed ref stage,
    /// indexed by absolute position (`ref_logp[p] = log P(tok_p | tok_<p)`,
    /// with the position-0 convention of `ref_logprobs`); grows with
    /// `streamed` and covers `total_len()` once the flush join completes
    pub ref_logp: Vec<f32>,
    /// number of PPO steps this sequence was deferred past its first
    /// eligible step (Table 2's metric); filled at batch selection
    pub deferred_steps: u64,
    /// chunk tick at which the prompt entered the admission queue
    /// (rolling admission; == `admitted_tick` under saturated arrivals)
    pub enqueued_tick: u64,
    /// chunk tick at which the prompt was admitted to a lane
    pub admitted_tick: u64,
    /// chunk tick at which generation finished (stamped by the scheduler;
    /// 0 until then) — `finished_tick - enqueued_tick` is the end-to-end
    /// latency, `admitted_tick - enqueued_tick` the queue wait
    pub finished_tick: u64,
    /// admitted mid-step: ineligible for the *current* step's PPO batch
    /// (cleared at the next step boundary by `SeqBuffer::promote_admitted`,
    /// which is what keeps Δ=0 saturated rolling step-equivalent to the
    /// legacy fixed-grid loop)
    pub mid_step: bool,
    /// permanent record that this sequence entered mid-step (telemetry;
    /// never cleared, unlike the eligibility flag above)
    pub admitted_mid_step: bool,
}

impl Sequence {
    pub fn new(prompt: Prompt, lane: usize, step: u64) -> Self {
        let prompt_len = prompt.tokens.len();
        Self {
            prompt,
            lane,
            phase: SeqPhase::Queued,
            prompt_len,
            response: Vec::new(),
            logps: Vec::new(),
            values: Vec::new(),
            enqueued_step: step,
            streamed: 0,
            rm_score: None,
            ref_logp: Vec::new(),
            deferred_steps: 0,
            enqueued_tick: 0,
            admitted_tick: 0,
            finished_tick: 0,
            mid_step: false,
            admitted_mid_step: false,
        }
    }

    /// Total committed length (prompt + response) — also the lane's `pos`.
    pub fn total_len(&self) -> usize {
        self.prompt_len + self.response.len()
    }

    pub fn is_finished(&self) -> bool {
        self.phase == SeqPhase::Finished
    }

    /// Append a generated token; returns true if this token finished the
    /// sequence (EOS or cap).
    pub fn push_token(
        &mut self,
        tok: i32,
        logp: f32,
        value: f32,
        eos: i32,
        max_new: usize,
        s_max: usize,
    ) -> bool {
        debug_assert_eq!(self.phase, SeqPhase::Generating);
        self.response.push(tok);
        self.logps.push(logp);
        self.values.push(value);
        let done = tok == eos
            || self.response.len() >= max_new
            || self.total_len() >= s_max;
        if done {
            self.phase = SeqPhase::Finished;
        }
        done
    }

    /// Tokens not yet streamed to the downstream stages (prompt + response).
    pub fn unstreamed(&self) -> usize {
        self.total_len().saturating_sub(self.streamed)
    }

    /// Full token row (prompt + response) — used for monolithic scoring.
    pub fn full_tokens(&self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.total_len());
        out.extend_from_slice(&self.prompt.tokens);
        out.extend_from_slice(&self.response);
        out
    }

    /// Response length excluding a trailing EOS (the scored answer text ends
    /// before EOS, but EOS itself is still a trained token).
    pub fn response_len(&self) -> usize {
        self.response.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::{Prompt, TaskKind};

    fn prompt(n: usize) -> Prompt {
        Prompt {
            kind: TaskKind::Arith,
            text: "1+1=".into(),
            tokens: vec![1; n],
            answer: "2".into(),
            id: 0,
        }
    }

    #[test]
    fn lifecycle_and_lengths() {
        let mut s = Sequence::new(prompt(5), 3, 7);
        s.phase = SeqPhase::Generating;
        assert_eq!(s.total_len(), 5);
        assert!(!s.push_token(10, -0.5, 0.1, 2, 8, 100));
        assert!(!s.push_token(11, -0.4, 0.2, 2, 8, 100));
        assert_eq!(s.total_len(), 7);
        assert_eq!(s.response_len(), 2);
        assert!(s.push_token(2, -0.1, 0.3, 2, 8, 100)); // EOS
        assert!(s.is_finished());
        assert_eq!(s.full_tokens().len(), 8);
    }

    #[test]
    fn cap_finishes_sequence() {
        let mut s = Sequence::new(prompt(3), 0, 0);
        s.phase = SeqPhase::Generating;
        for i in 0..3 {
            let done = s.push_token(10 + i, 0.0, 0.0, 2, 3, 100);
            assert_eq!(done, i == 2);
        }
        assert!(s.is_finished());
    }

    #[test]
    fn s_max_cap_finishes_sequence() {
        let mut s = Sequence::new(prompt(9), 0, 0);
        s.phase = SeqPhase::Generating;
        assert!(s.push_token(10, 0.0, 0.0, 2, 100, 10));
        assert!(s.is_finished());
    }

    #[test]
    fn unstreamed_accounting() {
        let mut s = Sequence::new(prompt(4), 0, 0);
        s.phase = SeqPhase::Generating;
        assert_eq!(s.unstreamed(), 4);
        s.streamed = 4;
        s.push_token(10, 0.0, 0.0, 2, 8, 100);
        assert_eq!(s.unstreamed(), 1);
        s.streamed = 5;
        assert_eq!(s.unstreamed(), 0);
    }
}
