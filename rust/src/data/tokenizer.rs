//! Character tokenizer over the model's small vocabulary.
//!
//! The authoritative token table lives in `artifacts/manifest.json` (written
//! by `python/compile/aot.py`); this mirrors it so Rust-side encoding is
//! guaranteed consistent with the embeddings the model was built with.
//! A built-in copy of the same table supports manifest-free unit tests.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::util::json::Value;

/// Special token ids (fixed by `python/compile/model.py`).
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    table: Vec<String>,
    by_char: HashMap<char, i32>,
}

impl Tokenizer {
    /// Build from the manifest's `tokenizer` object.
    pub fn from_manifest(tok: &Value) -> Result<Self> {
        let table: Vec<String> = tok
            .get("table")?
            .as_arr()?
            .iter()
            .map(|v| v.as_str().map(str::to_string))
            .collect::<Result<_>>()?;
        if tok.get("pad")?.as_i64()? != PAD as i64
            || tok.get("bos")?.as_i64()? != BOS as i64
            || tok.get("eos")?.as_i64()? != EOS as i64
        {
            bail!("manifest special-token ids disagree with the compiled constants");
        }
        Self::from_table(table)
    }

    /// The same table `aot.py` writes, for tests that run without artifacts.
    pub fn builtin(vocab: usize) -> Self {
        let mut table: Vec<String> =
            ["<pad>", "<bos>", "<eos>"].iter().map(|s| s.to_string()).collect();
        let ascii = " 0123456789abcdefghijklmnopqrstuvwxyz+-*/=?.,:;#|()[]<>";
        table.extend(ascii.chars().map(String::from));
        let mut i = 0;
        while table.len() < vocab {
            table.push(format!("<unused{i}>"));
            i += 1;
        }
        Self::from_table(table).expect("builtin table is valid")
    }

    fn from_table(table: Vec<String>) -> Result<Self> {
        let mut by_char = HashMap::new();
        for (i, entry) in table.iter().enumerate() {
            let mut chars = entry.chars();
            if let (Some(c), None) = (chars.next(), chars.next()) {
                if by_char.insert(c, i as i32).is_some() {
                    bail!("duplicate char {c:?} in token table");
                }
            }
        }
        Ok(Self { table, by_char })
    }

    pub fn vocab(&self) -> usize {
        self.table.len()
    }

    /// Encode text; unknown characters fail loudly (the synthetic tasks only
    /// emit in-alphabet text, so an unknown char is a bug).
    pub fn encode(&self, text: &str) -> Result<Vec<i32>> {
        text.chars()
            .map(|c| {
                self.by_char
                    .get(&c)
                    .copied()
                    .ok_or_else(|| anyhow::anyhow!("character {c:?} not in vocab"))
            })
            .collect()
    }

    /// Decode ids, skipping specials; out-of-range ids render as `¿`.
    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .filter(|&&id| id != PAD && id != BOS && id != EOS)
            .map(|&id| {
                self.table
                    .get(id as usize)
                    .filter(|e| e.chars().count() == 1)
                    .map(|e| e.chars().next().unwrap())
                    .unwrap_or('¿')
            })
            .collect()
    }

    /// Decode up to (excluding) the first EOS after `start`.
    pub fn decode_until_eos(&self, ids: &[i32], start: usize) -> String {
        let end = ids[start..]
            .iter()
            .position(|&t| t == EOS)
            .map(|p| start + p)
            .unwrap_or(ids.len());
        self.decode(&ids[start..end])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let tok = Tokenizer::builtin(64);
        let text = "12+34=46";
        let ids = tok.encode(text).unwrap();
        assert_eq!(tok.decode(&ids), text);
    }

    #[test]
    fn specials_are_skipped_in_decode() {
        let tok = Tokenizer::builtin(64);
        let mut ids = vec![BOS];
        ids.extend(tok.encode("ab").unwrap());
        ids.push(EOS);
        ids.push(PAD);
        assert_eq!(tok.decode(&ids), "ab");
    }

    #[test]
    fn decode_until_eos_stops() {
        let tok = Tokenizer::builtin(64);
        let mut ids = tok.encode("abc").unwrap();
        ids.push(EOS);
        ids.extend(tok.encode("zzz").unwrap());
        assert_eq!(tok.decode_until_eos(&ids, 0), "abc");
        assert_eq!(tok.decode_until_eos(&ids, 1), "bc");
    }

    #[test]
    fn unknown_char_errors() {
        let tok = Tokenizer::builtin(64);
        assert!(tok.encode("ABC").is_err()); // uppercase not in alphabet
    }

    #[test]
    fn builtin_matches_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let manifest = crate::util::json::parse(&text).unwrap();
            let from_manifest =
                Tokenizer::from_manifest(manifest.get("tokenizer").unwrap()).unwrap();
            let builtin = Tokenizer::builtin(from_manifest.vocab());
            assert_eq!(builtin.table, from_manifest.table);
        }
    }
}
