//! Summary statistics + histogram helpers for metrics and bench tables.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Sliding-window mean of the last `w` entries (used by the Δ controller).
pub fn tail_mean(xs: &[f64], w: usize) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let start = xs.len().saturating_sub(w);
    mean(&xs[start..])
}

/// Fixed-bin histogram over [lo, hi); values outside are clamped to the
/// edge bins.  Returns (bin_edges, counts).
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> (Vec<f64>, Vec<usize>) {
    assert!(bins > 0 && hi > lo);
    let width = (hi - lo) / bins as f64;
    let edges: Vec<f64> = (0..=bins).map(|i| lo + i as f64 * width).collect();
    let mut counts = vec![0usize; bins];
    for &x in xs {
        let idx = (((x - lo) / width) as isize).clamp(0, bins as isize - 1) as usize;
        counts[idx] += 1;
    }
    (edges, counts)
}

/// Ordinary least squares slope of y over x (the Δ controller's reward
/// trend `s_t`); 0.0 when degenerate.
pub fn ols_slope(x: &[f64], y: &[f64]) -> f64 {
    if x.len() != y.len() || x.len() < 2 {
        return 0.0;
    }
    let mx = mean(x);
    let my = mean(y);
    let mut num = 0.0;
    let mut den = 0.0;
    for (xi, yi) in x.iter().zip(y) {
        num += (xi - mx) * (yi - my);
        den += (xi - mx) * (xi - mx);
    }
    if den.abs() < 1e-12 {
        0.0
    } else {
        num / den
    }
}

/// Running mean/var (Welford) — allocation-free accumulation in hot loops.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts_and_clamps() {
        let xs = [-1.0, 0.1, 0.2, 0.5, 0.9, 2.0];
        let (edges, counts) = histogram(&xs, 0.0, 1.0, 2);
        assert_eq!(edges.len(), 3);
        assert_eq!(counts, vec![3, 3]); // -1 clamps low, 2.0 clamps high
    }

    #[test]
    fn slope_signs() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let up: Vec<f64> = x.iter().map(|v| 2.0 * v + 1.0).collect();
        let down: Vec<f64> = x.iter().map(|v| -0.5 * v).collect();
        assert!((ols_slope(&x, &up) - 2.0).abs() < 1e-9);
        assert!((ols_slope(&x, &down) + 0.5).abs() < 1e-9);
        assert_eq!(ols_slope(&x[..1], &up[..1]), 0.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.5];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std(&xs)).abs() < 1e-12);
    }

    #[test]
    fn tail_mean_window() {
        let xs = [0.0, 0.0, 3.0, 5.0];
        assert!((tail_mean(&xs, 2) - 4.0).abs() < 1e-12);
        assert!((tail_mean(&xs, 100) - 2.0).abs() < 1e-12);
    }
}
