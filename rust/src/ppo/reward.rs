//! Per-token reward composition (§2.1): sequence-level score at the final
//! response token plus an InstructGPT-style per-token KL penalty
//! `-β (log π_actor − log π_ref)` that regularizes the policy toward the
//! frozen reference model.

/// Inputs for one sequence's reward vector.
pub struct RewardInputs<'a> {
    /// scalar score for the full sequence (reward model and/or rule)
    pub score: f32,
    /// actor log-probs of the response tokens (length = response len)
    pub actor_logp: &'a [f32],
    /// reference log-probs of the same tokens
    pub ref_logp: &'a [f32],
    /// KL coefficient β
    pub kl_beta: f32,
}

/// Compose the per-token reward row for one sequence.
///
/// Returns a vector with one entry per response token: every token gets the
/// KL term; the last token additionally receives the sequence score.
pub fn compose_rewards(inp: &RewardInputs) -> Vec<f32> {
    assert_eq!(inp.actor_logp.len(), inp.ref_logp.len());
    let n = inp.actor_logp.len();
    let mut out = Vec::with_capacity(n);
    for t in 0..n {
        let mut r = -inp.kl_beta * (inp.actor_logp[t] - inp.ref_logp[t]);
        if t + 1 == n {
            r += inp.score;
        }
        out.push(r);
    }
    out
}

/// Blend a learned reward-model score with the rule reward (the paper runs
/// both RM-scored and rule-based settings; §4.1).
pub fn blend_score(rm_score: f32, rule_score: f32, rm_weight: f64) -> f32 {
    let w = rm_weight as f32;
    w * rm_score + (1.0 - w) * rule_score
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kl_term_signs() {
        // actor more confident than ref => positive KL => negative reward
        let r = compose_rewards(&RewardInputs {
            score: 0.0,
            actor_logp: &[-0.1, -0.1],
            ref_logp: &[-1.0, -1.0],
            kl_beta: 0.5,
        });
        assert!(r.iter().all(|&x| x < 0.0));
        // actor on-reference => zero KL penalty
        let r = compose_rewards(&RewardInputs {
            score: 2.0,
            actor_logp: &[-0.3, -0.3],
            ref_logp: &[-0.3, -0.3],
            kl_beta: 0.5,
        });
        assert_eq!(r, vec![0.0, 2.0]);
    }

    #[test]
    fn score_lands_on_last_token_only() {
        let r = compose_rewards(&RewardInputs {
            score: 3.0,
            actor_logp: &[-1.0, -1.0, -1.0],
            ref_logp: &[-1.0, -1.0, -1.0],
            kl_beta: 0.1,
        });
        assert_eq!(r, vec![0.0, 0.0, 3.0]);
    }

    #[test]
    fn empty_response_is_empty() {
        let r = compose_rewards(&RewardInputs {
            score: 1.0,
            actor_logp: &[],
            ref_logp: &[],
            kl_beta: 0.1,
        });
        assert!(r.is_empty());
    }

    #[test]
    fn blend_endpoints() {
        assert_eq!(blend_score(2.0, -1.0, 1.0), 2.0);
        assert_eq!(blend_score(2.0, -1.0, 0.0), -1.0);
        assert!((blend_score(2.0, -1.0, 0.25) - (-0.25)).abs() < 1e-6);
    }
}
