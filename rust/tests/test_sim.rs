//! Simulator integration: multi-seed stability of the headline shapes.
use oppo::sim::pipeline::{simulate, steady_state_latency, Pipeline, SimConfig};
use oppo::sim::presets;

#[test]
fn speedups_hold_across_seeds_and_setups() {
    for setup in presets::all_main_setups() {
        for seed in [1u64, 2, 3] {
            let cfg = SimConfig::new(setup.clone(), 60, seed);
            let trl = steady_state_latency(&simulate(Pipeline::TrlSequential, &cfg));
            let oppo = steady_state_latency(&simulate(Pipeline::oppo(), &cfg));
            let ratio = trl / oppo;
            assert!(
                (1.3..4.5).contains(&ratio),
                "{} seed {seed}: per-step speedup {ratio}",
                setup.name
            );
        }
    }
}

#[test]
fn fixed_delta_variants_bracket_dynamic() {
    let setup = presets::stackex_3b_a100();
    let lat = |p| {
        steady_state_latency(&simulate(p, &SimConfig::new(setup.clone(), 80, 5)))
    };
    let d4 = lat(Pipeline::Oppo { intra: true, inter: true, fixed_delta: Some(4) });
    let trl = lat(Pipeline::TrlSequential);
    assert!(d4 < trl, "even Δ=4 must beat TRL: {d4} vs {trl}");
}

#[test]
fn conservation_every_step_trains_exactly_b() {
    let setup = presets::stackex_7b_h200();
    let cfg = SimConfig::new(setup.clone(), 50, 9);
    let log = simulate(Pipeline::oppo(), &cfg);
    for r in &log.records {
        assert_eq!(r.finished, setup.batch, "step {} trained on {}", r.step, r.finished);
        assert!(r.deferred <= setup.batch + setup.delta_max);
    }
}

#[test]
fn multinode_gap_exceeds_single_node() {
    let single = presets::stackex_7b_h200();
    let multi = presets::multinode_7b_a100_40();
    let ratio = |setup: &presets::Setup| {
        let cfg = SimConfig::new(setup.clone(), 50, 4);
        steady_state_latency(&simulate(Pipeline::TrlSequential, &cfg))
            / steady_state_latency(&simulate(Pipeline::oppo(), &cfg))
    };
    assert!(ratio(&multi) > ratio(&single) * 1.15,
        "multi-node should amplify OPPO's advantage");
}
