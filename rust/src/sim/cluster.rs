//! Cluster topology: GPU pools, scoring placement, nodes, interconnect.

use super::gpu::GpuSpec;

/// Hardware + placement for one experiment (the paper's §4.1 setups:
/// "seven GPUs to the generation and training stages, and one GPU to the
/// scoring stage").
#[derive(Clone, Copy, Debug)]
pub struct ClusterSetup {
    pub gpu: GpuSpec,
    /// GPUs in the generation + training pool
    pub n_gen: usize,
    /// GPUs dedicated to reward-model scoring (0 ⇒ colocated or rule-based)
    pub n_score: usize,
    /// number of nodes the gen pool spans
    pub nodes: usize,
    /// inter-node bandwidth, Gb/s (0 ⇒ single node / NVLink only)
    pub network_gbps: f64,
    /// true when the reward model shares the generation GPUs
    pub colocated_scoring: bool,
}

impl ClusterSetup {
    /// The paper's default 8-GPU split: 7 gen/train + 1 score.
    pub fn single_node(gpu: GpuSpec, n_gen: usize, n_score: usize) -> Self {
        Self { gpu, n_gen, n_score, nodes: 1, network_gbps: 0.0, colocated_scoring: n_score == 0 }
    }

    /// Table 1's two-node setup: 2 × 4×A100-40GB over 100 Gb/s IB.
    pub fn two_node_a100_40() -> Self {
        Self {
            gpu: GpuSpec::A100_40,
            n_gen: 7,
            n_score: 1,
            nodes: 2,
            network_gbps: 100.0,
            colocated_scoring: false,
        }
    }

    pub fn total_gpus(&self) -> usize {
        self.n_gen + self.n_score
    }

    /// Cross-node communication is on the training path iff multi-node.
    pub fn train_network_gbps(&self) -> f64 {
        if self.nodes > 1 {
            self.network_gbps
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_split() {
        let c = ClusterSetup::single_node(GpuSpec::H200, 7, 1);
        assert_eq!(c.total_gpus(), 8);
        assert!(!c.colocated_scoring);
        assert_eq!(c.train_network_gbps(), 0.0);
    }

    #[test]
    fn colocation_when_no_score_gpu() {
        let c = ClusterSetup::single_node(GpuSpec::GH200_96, 4, 0);
        assert!(c.colocated_scoring);
    }

    #[test]
    fn multinode_exposes_network() {
        let c = ClusterSetup::two_node_a100_40();
        assert_eq!(c.nodes, 2);
        assert_eq!(c.train_network_gbps(), 100.0);
    }
}
