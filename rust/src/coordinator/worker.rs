//! The downstream stage workers — reward scoring and reference log-probs —
//! built on the generic [`StageWorker`](crate::coordinator::stage)
//! runtime, plus [`StreamSink`], the scheduler-side facade that fans one
//! streamed `[G, C]` chunk out to every active stage.
//!
//! This is the concurrency that realizes §3.1's intra-step overlap: while
//! the actor thread executes `actor_generate_chunk` for chunk *k*, the
//! reward thread executes `reward_prefill_chunk` and the ref thread
//! `ref_prefill_chunk` for chunk *k−1*.  PJRT executes all of them
//! concurrently (thread-safe client), so downstream prefill latency hides
//! behind actor decoding exactly as in the paper's Figure 1b — now for
//! *every* downstream model, not just reward.  Each worker owns its own
//! parameters and KV state, constructed on its own thread.

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::buffer::SeqBuffer;
use crate::coordinator::engine_ops::{RefOps, RefStreamState, RewardOps, RewardState};
use crate::coordinator::stage::{StageHandler, StageWorker};
use crate::metrics::StageTiming;
use crate::model::sequence::Sequence;
use crate::runtime::Engine;

/// Which lane positions hold a sequence's *final* token in this chunk —
/// the reward worker returns the score read off at exactly those positions.
#[derive(Clone, Debug)]
pub struct Pick {
    pub lane: usize,
    pub idx_in_chunk: usize,
}

/// One streamed `[G, C]` chunk of actor output, built once per decode
/// iteration and fanned out to every active downstream stage.
#[derive(Clone, Debug)]
pub struct StreamChunk {
    /// chunk size C
    pub c: usize,
    /// row-major [G, C] token chunk (PAD-filled for idle lanes)
    pub tokens: Vec<i32>,
    /// per-lane absolute start position
    pub start: Vec<i32>,
    /// per-lane number of valid tokens in the chunk
    pub n_valid: Vec<i32>,
    /// lanes whose final token lands in this chunk
    pub picks: Vec<Pick>,
}

// ---------------------------------------------------------------------------
// reward stage
// ---------------------------------------------------------------------------

/// Requests to the reward worker.
pub enum RewardReq {
    /// Incremental prefill of one streamed chunk (intra-step overlap).
    Stream {
        /// entry name (`reward_prefill_chunk_c{C}` or the pallas flavour)
        entry: String,
        chunk: Vec<i32>,
        start: Vec<i32>,
        n_valid: Vec<i32>,
        /// final-token positions to read scores from
        picks: Vec<Pick>,
    },
    /// Monolithic scoring (baselines / ablation w/o intra).
    ScoreFull { tokens: Vec<i32>, last_idx: Vec<i32> },
    /// Reset the reward KV state (new run / tests).
    Reset,
}

/// Worker responses (tagged and in submission order).
#[derive(Debug)]
pub enum RewardResp {
    /// (lane, score) for each pick in the stream request
    StreamScores(Vec<(usize, f32)>),
    /// all-lane scores for a ScoreFull request
    FullScores(Vec<f32>),
    /// acknowledgement of Reset
    ResetDone,
}

struct RewardHandler {
    ops: RewardOps,
    state: RewardState,
}

impl StageHandler for RewardHandler {
    type Req = RewardReq;
    type Resp = RewardResp;

    fn handle(&mut self, req: RewardReq) -> Result<RewardResp> {
        match req {
            RewardReq::Reset => {
                self.state = self.ops.fresh_state()?;
                Ok(RewardResp::ResetDone)
            }
            RewardReq::Stream { entry, chunk, start, n_valid, picks } => {
                let g = start.len();
                let c = chunk.len() / g;
                let scores =
                    self.ops.prefill_chunk(&mut self.state, &entry, &chunk, &start, &n_valid)?;
                Ok(RewardResp::StreamScores(
                    picks
                        .iter()
                        .map(|p| (p.lane, scores[p.lane * c + p.idx_in_chunk]))
                        .collect(),
                ))
            }
            RewardReq::ScoreFull { tokens, last_idx } => {
                Ok(RewardResp::FullScores(self.ops.score_full(&tokens, &last_idx)?))
            }
        }
    }
}

/// Handle to the reward stage worker.
pub struct RewardWorker {
    inner: StageWorker<RewardReq, RewardResp>,
}

impl RewardWorker {
    pub fn spawn(engine: Arc<Engine>, queue_depth: usize) -> Result<Self> {
        let inner = StageWorker::spawn("reward", queue_depth, move || {
            let ops = RewardOps::new(engine)?;
            let state = ops.fresh_state()?;
            Ok(RewardHandler { ops, state })
        })?;
        Ok(Self { inner })
    }

    /// Enqueue a request (bounded queue; blocks only under backpressure).
    pub fn submit(&mut self, req: RewardReq) -> Result<()> {
        self.inner.submit(req).map(|_| ())
    }

    /// Block for the next response.
    pub fn recv(&mut self) -> Result<RewardResp> {
        self.inner.recv().map(|(_, r)| r)
    }

    pub fn try_recv(&mut self) -> Result<Option<RewardResp>> {
        Ok(self.inner.try_recv()?.map(|(_, r)| r))
    }

    pub fn in_flight(&self) -> usize {
        self.inner.in_flight()
    }

    pub fn timing_delta(&mut self) -> StageTiming {
        self.inner.timing_delta()
    }
}

// ---------------------------------------------------------------------------
// reference stage
// ---------------------------------------------------------------------------

/// Requests to the reference worker.
pub enum RefReq {
    /// Incremental ref-logprob prefill of one streamed chunk.
    Stream { entry: String, chunk: Vec<i32>, start: Vec<i32>, n_valid: Vec<i32> },
    /// Reset the ref KV/boundary state (new run / tests).
    Reset,
}

#[derive(Debug)]
pub enum RefResp {
    /// raw [G, C] log-probs for a stream request (garbage at j >= n_valid)
    StreamLogps(Vec<f32>),
    ResetDone,
}

struct RefHandler {
    ops: RefOps,
    state: RefStreamState,
}

impl StageHandler for RefHandler {
    type Req = RefReq;
    type Resp = RefResp;

    fn handle(&mut self, req: RefReq) -> Result<RefResp> {
        match req {
            RefReq::Reset => {
                self.state = self.ops.fresh_state()?;
                Ok(RefResp::ResetDone)
            }
            RefReq::Stream { entry, chunk, start, n_valid } => Ok(RefResp::StreamLogps(
                self.ops.prefill_chunk(&mut self.state, &entry, &chunk, &start, &n_valid)?,
            )),
        }
    }
}

/// Handle to the reference stage worker.
pub struct RefWorker {
    inner: StageWorker<RefReq, RefResp>,
}

impl RefWorker {
    pub fn spawn(engine: Arc<Engine>, queue_depth: usize) -> Result<Self> {
        let inner = StageWorker::spawn("ref", queue_depth, move || {
            let ops = RefOps::new(engine)?;
            let state = ops.fresh_state()?;
            Ok(RefHandler { ops, state })
        })?;
        Ok(Self { inner })
    }

    pub fn submit(&mut self, req: RefReq) -> Result<()> {
        self.inner.submit(req).map(|_| ())
    }

    pub fn recv(&mut self) -> Result<RefResp> {
        self.inner.recv().map(|(_, r)| r)
    }

    pub fn try_recv(&mut self) -> Result<Option<RefResp>> {
        Ok(self.inner.try_recv()?.map(|(_, r)| r))
    }

    pub fn in_flight(&self) -> usize {
        self.inner.in_flight()
    }

    pub fn timing_delta(&mut self) -> StageTiming {
        self.inner.timing_delta()
    }
}

// ---------------------------------------------------------------------------
// fan-out facade
// ---------------------------------------------------------------------------

/// Ref sink bookkeeping: responses are raw `[G, C]` log-prob grids, so the
/// per-request `(start, n_valid, c)` metadata rides a FIFO alongside the
/// in-flight requests (the worker answers strictly in submission order).
pub struct RefSink {
    worker: RefWorker,
    meta: VecDeque<(Vec<i32>, Vec<i32>, usize)>,
}

impl RefSink {
    pub fn spawn(engine: Arc<Engine>, queue_depth: usize) -> Result<Self> {
        Ok(Self { worker: RefWorker::spawn(engine, queue_depth)?, meta: VecDeque::new() })
    }

    fn apply(&mut self, buf: &mut SeqBuffer, logps: Vec<f32>) -> Result<()> {
        let (start, n_valid, c) = self
            .meta
            .pop_front()
            .context("ref stage response without a matching request")?;
        for lane in 0..start.len() {
            let nv = n_valid[lane] as usize;
            if nv == 0 {
                continue;
            }
            let seq = buf
                .by_lane_mut(lane)
                .with_context(|| format!("ref response for vacated lane {lane}"))?;
            let st = start[lane] as usize;
            ensure!(
                seq.ref_logp.len() == st,
                "ref stream discontinuity on lane {lane}: have {} positions, chunk starts at {st}",
                seq.ref_logp.len()
            );
            seq.ref_logp.extend_from_slice(&logps[lane * c..lane * c + nv]);
        }
        Ok(())
    }
}

/// Scheduler-side handle to one active downstream stage.  The step loop
/// fans every [`StreamChunk`] out to all sinks and joins them at flush;
/// future stages (critic, sharded reward replicas) add a variant here and
/// a worker above, and the scheduler loop stays untouched.
pub enum StreamSink {
    Reward(RewardWorker),
    Ref(RefSink),
}

impl StreamSink {
    pub fn name(&self) -> &'static str {
        match self {
            StreamSink::Reward(_) => "reward",
            StreamSink::Ref(_) => "ref",
        }
    }

    /// Submit one streamed chunk to this stage (typed per-stage request).
    pub fn submit_chunk(&mut self, ck: &StreamChunk) -> Result<()> {
        match self {
            StreamSink::Reward(w) => w.submit(RewardReq::Stream {
                entry: format!("reward_prefill_chunk_c{}", ck.c),
                chunk: ck.tokens.clone(),
                start: ck.start.clone(),
                n_valid: ck.n_valid.clone(),
                picks: ck.picks.clone(),
            }),
            StreamSink::Ref(s) => {
                s.meta.push_back((ck.start.clone(), ck.n_valid.clone(), ck.c));
                s.worker.submit(RefReq::Stream {
                    entry: format!("ref_prefill_chunk_c{}", ck.c),
                    chunk: ck.tokens.clone(),
                    start: ck.start.clone(),
                    n_valid: ck.n_valid.clone(),
                })
            }
        }
    }

    /// Apply any responses that are already available (non-blocking).
    pub fn collect_ready(&mut self, buf: &mut SeqBuffer) -> Result<()> {
        loop {
            match self {
                StreamSink::Reward(w) => match w.try_recv()? {
                    Some(resp) => apply_reward(buf, resp)?,
                    None => return Ok(()),
                },
                StreamSink::Ref(s) => match s.worker.try_recv()? {
                    Some(RefResp::StreamLogps(lp)) => s.apply(buf, lp)?,
                    Some(other) => bail!("unexpected ref response {other:?}"),
                    None => return Ok(()),
                },
            }
        }
    }

    /// Block until every in-flight response is applied (the flush join).
    pub fn join(&mut self, buf: &mut SeqBuffer) -> Result<()> {
        match self {
            StreamSink::Reward(w) => {
                while w.in_flight() > 0 {
                    let resp = w.recv()?;
                    apply_reward(buf, resp)?;
                }
            }
            StreamSink::Ref(s) => {
                while s.worker.in_flight() > 0 {
                    match s.worker.recv()? {
                        RefResp::StreamLogps(lp) => s.apply(buf, lp)?,
                        other => bail!("unexpected ref response {other:?}"),
                    }
                }
            }
        }
        Ok(())
    }

    /// Does this stage hold everything it needs for `seq`?  Checked for
    /// finished sequences when deciding whether the flush loop must keep
    /// streaming.
    pub fn is_satisfied(&self, seq: &Sequence) -> bool {
        match self {
            StreamSink::Reward(_) => seq.rm_score.is_some(),
            StreamSink::Ref(_) => seq.ref_logp.len() >= seq.total_len(),
        }
    }

    pub fn timing_delta(&mut self) -> StageTiming {
        match self {
            StreamSink::Reward(w) => w.timing_delta(),
            StreamSink::Ref(s) => s.worker.timing_delta(),
        }
    }
}

fn apply_reward(buf: &mut SeqBuffer, resp: RewardResp) -> Result<()> {
    match resp {
        RewardResp::StreamScores(scores) => {
            for (lane, score) in scores {
                if let Some(seq) = buf.by_lane_mut(lane) {
                    seq.rm_score = Some(score);
                }
            }
            Ok(())
        }
        other => bail!("unexpected reward response {other:?}"),
    }
}
