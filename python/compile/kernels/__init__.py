"""L1 — Pallas kernels for OPPO's compute hot-spots, plus pure-jnp oracles.

``select(impl)`` returns a namespace exposing the three kernel entry points
(``chunked_prefill_attention``, ``decode_attention``, ``gae``) backed either
by the Pallas kernels (``"pallas"``, interpret mode — the TPU-schedule
implementation) or by the jnp oracles (``"jnp"`` — the XLA-fused flavour the
long-running AOT artifacts default to; see DESIGN.md §7 and EXPERIMENTS.md
§Perf for the measured tradeoff).
"""

from types import SimpleNamespace

from . import attention, decode, gae, ref


def select(impl: str) -> SimpleNamespace:
    if impl == "pallas":
        return SimpleNamespace(
            chunked_prefill_attention=attention.chunked_prefill_attention,
            decode_attention=decode.decode_attention,
            gae=gae.gae,
        )
    if impl == "jnp":
        return SimpleNamespace(
            chunked_prefill_attention=ref.chunked_prefill_attention,
            decode_attention=ref.decode_attention,
            gae=ref.gae,
        )
    raise ValueError(f"unknown kernel impl {impl!r} (want 'pallas' or 'jnp')")
