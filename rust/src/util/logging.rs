//! A tiny `log` backend with wall-clock timestamps (no env_logger offline).

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use once_cell::sync::Lazy;

static START: Lazy<Instant> = Lazy::new(Instant::now);
static INSTALLED: AtomicBool = AtomicBool::new(false);

struct StderrLogger {
    level: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            let t = START.elapsed().as_secs_f64();
            eprintln!("[{t:9.3}s {:5} {}] {}", record.level(), record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

/// Install the logger once; later calls are no-ops.  Level comes from
/// `OPPO_LOG` (error|warn|info|debug|trace), default `info`.
pub fn init() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = match std::env::var("OPPO_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        Ok("off") => log::LevelFilter::Off,
        _ => log::LevelFilter::Info,
    };
    let _ = log::set_boxed_logger(Box::new(StderrLogger { level }));
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }
}
