//! Long-tailed, phase-evolving response-length distributions (Fig. 2b).
//!
//! Lengths are lognormal (median `exp(mu)`, tail weight `sigma`) truncated
//! at `max_len`.  `mu`/`sigma` interpolate between a warm-up profile and a
//! converged profile as training progresses — the paper's observation that
//! "the length distribution evolves across stages", which is what defeats
//! static GPU-allocation tuning and motivates the *dynamic* Δ controller.

use crate::util::rng::Rng;

/// One phase's lognormal parameters.
#[derive(Clone, Copy, Debug)]
pub struct Phase {
    pub mu: f64,
    pub sigma: f64,
}

/// Evolving length model.
#[derive(Clone, Debug)]
pub struct LengthModel {
    pub warmup: Phase,
    pub converged: Phase,
    pub max_len: f64,
}

impl LengthModel {
    /// Interpolated parameters at training progress `p ∈ [0, 1]`.
    pub fn phase_at(&self, p: f64) -> Phase {
        let p = p.clamp(0.0, 1.0);
        Phase {
            mu: self.warmup.mu + (self.converged.mu - self.warmup.mu) * p,
            sigma: self.warmup.sigma + (self.converged.sigma - self.warmup.sigma) * p,
        }
    }

    /// Sample one response length at progress `p`.
    pub fn sample(&self, rng: &mut Rng, p: f64) -> f64 {
        let ph = self.phase_at(p);
        rng.lognormal(ph.mu, ph.sigma).clamp(1.0, self.max_len)
    }

    /// Sample a batch of lengths.
    pub fn sample_batch(&self, rng: &mut Rng, p: f64, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.sample(rng, p)).collect()
    }

    /// Median at progress `p` (analytic).
    pub fn median(&self, p: f64) -> f64 {
        self.phase_at(p).mu.exp().min(self.max_len)
    }

    /// Analytic tail ratio p99/median at progress `p` (untruncated):
    /// `exp(2.326 * sigma)`.
    pub fn tail_ratio(&self, p: f64) -> f64 {
        (2.326 * self.phase_at(p).sigma).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn model() -> LengthModel {
        LengthModel {
            warmup: Phase { mu: 6.0, sigma: 0.9 },
            converged: Phase { mu: 5.3, sigma: 0.6 },
            max_len: 8192.0,
        }
    }

    #[test]
    fn long_tail_at_warmup() {
        let m = model();
        let mut rng = Rng::new(1);
        let xs = m.sample_batch(&mut rng, 0.0, 20_000);
        let med = stats::percentile(&xs, 50.0);
        let p99 = stats::percentile(&xs, 99.0);
        assert!(p99 / med > 5.0, "tail ratio {}", p99 / med);
        assert!((med - 403.0).abs() < 40.0, "median {med} vs exp(6)≈403");
    }

    #[test]
    fn distribution_tightens_as_training_converges() {
        let m = model();
        let mut rng = Rng::new(2);
        let warm = m.sample_batch(&mut rng, 0.0, 20_000);
        let conv = m.sample_batch(&mut rng, 1.0, 20_000);
        let ratio = |xs: &[f64]| stats::percentile(xs, 99.0) / stats::percentile(xs, 50.0);
        assert!(ratio(&conv) < ratio(&warm), "{} !< {}", ratio(&conv), ratio(&warm));
        assert!(stats::percentile(&conv, 50.0) < stats::percentile(&warm, 50.0));
    }

    #[test]
    fn truncation_and_floor() {
        let m = LengthModel {
            warmup: Phase { mu: 9.0, sigma: 1.5 },
            converged: Phase { mu: 9.0, sigma: 1.5 },
            max_len: 1000.0,
        };
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let x = m.sample(&mut rng, 0.5);
            assert!((1.0..=1000.0).contains(&x));
        }
    }

    #[test]
    fn analytic_helpers_consistent() {
        let m = model();
        assert!(m.median(0.0) > m.median(1.0));
        assert!(m.tail_ratio(0.0) > m.tail_ratio(1.0));
        // interpolation midpoint
        let mid = m.phase_at(0.5);
        assert!((mid.mu - 5.65).abs() < 1e-9);
    }
}
